"""Schedule-aware pipeline search: enumeration invariants, the memory-cap
acceptance criteria (1F1B rescues plans GPipe's honest accounting rejects),
and elastic replans retaining pipeline parallelism."""
import dataclasses
import math

import pytest
from tests._prop import given, settings, st

from repro.configs.registry import get_config
from repro.core.cluster import TPU_V5E_POD
from repro.core.dynamic_programming import schedule_space
from repro.core.search import SearchEngine
from repro.core.strategy import ExecutionPlan, PP_SCHEDULES


# ---------------------------------------------------------------- enumeration
@settings(max_examples=40, deadline=None)
@given(pp=st.sampled_from([1, 2, 4, 8]),
       ga=st.integers(1, 64),
       L=st.sampled_from([4, 16, 24, 40]))
def test_schedule_space_invariants(pp, ga, L):
    space = schedule_space(pp, ga, L)
    assert ("gpipe", 1) in space                      # always realizable
    for sched, v in space:
        assert sched in PP_SCHEDULES
        if sched == "interleaved":
            assert v >= 2 and L % (pp * v) == 0       # runtime stage_stack gate
        else:
            assert v == 1
    if pp <= 1:
        assert space == [("gpipe", 1)]
    else:
        assert (("1f1b", 1) in space) == (max(ga, pp) % pp == 0)


def test_plan_validates_schedule():
    kw = dict(arch="a", shape="t", mesh_axes=("data",), mesh_shape=(1,))
    with pytest.raises(ValueError):
        ExecutionPlan(pp_schedule="zigzag", **kw)
    with pytest.raises(ValueError):
        ExecutionPlan(pp_schedule="interleaved", pp_interleave=1, **kw)
    with pytest.raises(ValueError):
        ExecutionPlan(pp_schedule="gpipe", pp_interleave=2, **kw)
    plan = ExecutionPlan(pp=2, pp_schedule="interleaved", pp_interleave=2, **kw)
    back = ExecutionPlan.from_json(plan.to_json())
    assert (back.pp_schedule, back.pp_interleave) == ("interleaved", 2)


# ---------------------------------------------------------------- memory cap
def _tiny_pp_cfg():
    return dataclasses.replace(get_config("llama3.2-1b").reduced(), num_layers=4)


def _load_schedule_bench():
    """The CI smoke (benchmarks/pipeline_schedules.py) owns the calibrated
    memory-cap scenario; load it by path so the test and the smoke share one
    implementation (benchmarks/ is not a package)."""
    import importlib.util
    import pathlib

    path = pathlib.Path(__file__).resolve().parents[1] / "benchmarks" / \
        "pipeline_schedules.py"
    spec = importlib.util.spec_from_file_location("_pipeline_schedules_bench",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_search_prefers_1f1b_under_memory_cap():
    """Acceptance: with grad_accum >= 2·pp, (a) the GPipe memory estimate
    strictly exceeds 1F1B's, and (c) the search returns a 1f1b plan when a
    GPipe-only search would exceed the memory cap (scenario shared with the
    CI smoke in benchmarks/pipeline_schedules.py --check)."""
    r = _load_schedule_bench().check(verbose=False)
    assert r["m_gpipe"] > r["m_1f1b"]                 # (a)
    cap = r["cap"]
    assert r["m_1f1b"] < 0.8 * cap and 1.2 * cap < r["m_gpipe"]  # calibration
    assert not r["only_gpipe"].feasible               # (c) gpipe alone OOMs
    best = r["best"]
    assert best.feasible and best.plan.pp_schedule == "1f1b"
    assert best.plan.predicted_memory <= cap
    assert best.plan.predicted_memory < r["m_gpipe"]


def test_pinned_non_power_of_two_interleave_is_searchable():
    """The default space explores power-of-two interleaves, but an explicit
    pp_schedule_options pin must accept any v the runtime can stage
    (num_layers % (pp·v) == 0) instead of silently dropping the combo."""
    cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(), num_layers=12)
    res = SearchEngine(cfg).search(
        512, 64, mesh_shape=(2, 2, 1), mesh_axes=("pod", "data", "model"),
        pp_options=[2], grad_accum_options=[4],
        pp_schedule_options=[("interleaved", 3)])
    assert res.feasible
    assert (res.plan.pp, res.plan.pp_schedule, res.plan.pp_interleave) == \
        (2, "interleaved", 3)


def test_search_skips_unsplittable_pp():
    """pp that does not divide num_layers cannot be staged by the runtime."""
    cfg = _tiny_pp_cfg()                              # 4 layers
    res = SearchEngine(cfg).search(
        512, 64, mesh_shape=(3, 2, 1), mesh_axes=("pod", "data", "model"),
        pp_options=[3], grad_accum_options=[4])
    assert not res.feasible or res.plan.pp == 1


# ---------------------------------------------------------------- elastic
def test_elastic_replan_retains_pipeline_parallelism():
    """Regression: replan hard-coded pp_options=[1], so a membership change
    silently dropped PP even when the surviving topology wants it.  On a
    cluster whose fast domains hold 16 chips, 512 surviving devices at pp=1
    push the dp=32 gradient ring onto the slow inter-domain links; pp=2 keeps
    each stage's dp=16 ring intra-domain and wins by an order of magnitude."""
    from repro.runtime.elastic import ElasticEvent, replan, replan_pp_candidates

    cfg = get_config("qwen3-14b")
    assert replan_pp_candidates(cfg, 512) == [1, 2, 4, 8]
    slow = dataclasses.replace(TPU_V5E_POD, intra_size=16, inter_bw=0.5e9)
    plan = replan(cfg, ElasticEvent(1024, 512, "node-failure"), 512, 32,
                  cluster=slow)
    assert plan.pp > 1
    assert "pod" in plan.mesh_axes
    assert "elastic replan" in plan.notes
    assert math.prod(plan.mesh_shape) <= 512


def test_elastic_replan_pp_candidates_gates():
    from repro.runtime.elastic import replan_pp_candidates

    moe = get_config("moonshot-v1-16b-a3b")           # experts -> no PP runtime
    assert replan_pp_candidates(moe, 256) == [1]
    dense = get_config("llama3.2-1b")                 # 16 layers
    assert replan_pp_candidates(dense, 256) == [1, 2, 4, 8]
    assert replan_pp_candidates(dense, 2) == [1, 2]
