"""End-to-end behaviour: the paper's workflow (Fig. 2) at reduced scale —
profile -> search -> construct_hybrid_parallel_model -> train -> checkpoint.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core import get_hybrid_parallel_configs
from repro.core.search import SearchEngine
from repro.core.strategy import ExecutionPlan, LayerStrategy
from repro.models import build_model
from repro.runtime import checkpoint as ckpt
from repro.runtime.data import SyntheticDataset
from repro.runtime.serve import ServingEngine
from repro.runtime.train import construct_hybrid_parallel_model


def test_paper_workflow_end_to_end(tmp_path, rng):
    cfg = get_config("llama3.2-1b").reduced()

    # step 1-3: profile + search (Fig. 2 line 9) — CPU-scale "cluster"
    plan_full = get_hybrid_parallel_configs(get_config("llama3.2-1b"), 4096, 256,
                                            mesh_shape=(16, 16),
                                            mesh_axes=("data", "model"),
                                            pp_options=[1])
    assert plan_full.predicted_step_time > 0

    # step 4: runtime executes a (reduced) hybrid plan
    strat = LayerStrategy(remat="selective")
    plan = ExecutionPlan(arch="llama3.2-1b", shape="t", mesh_axes=("data",),
                         mesh_shape=(1,), grad_accum=2,
                         layer_strategies=[strat] * cfg.num_layers,
                         default_strategy=strat)
    model = build_model(cfg)
    hp = construct_hybrid_parallel_model(model, plan)
    params, opt = hp.init_params(rng), None
    opt = hp.init_opt_state(params)
    ds = SyntheticDataset(cfg, seq_len=32, global_batch=4)
    step = hp.jit_train_step(donate=False)
    losses = []
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}   # fixed batch:
    for i in range(4):                                            # monotone descent
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]

    # fault tolerance: save, restore, resume deterministically
    ckpt.save(tmp_path, 4, hp.ungroup(params), opt, plan)
    restored = ckpt.restore(tmp_path, params_like=hp.ungroup(params), opt_like=opt)
    params_r = hp.group(jax.tree.map(jnp.asarray, restored["params"]))
    batch = {k: jnp.asarray(v) for k, v in ds.batch(9).items()}
    _, _, m1 = step(params, opt, batch)
    opt_r = jax.tree.map(jnp.asarray, restored["opt"],
                         is_leaf=lambda x: not isinstance(x, (dict, tuple, list)))
    _, _, m2 = step(params_r, opt_r, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)


def test_generation_produces_tokens(rng):
    cfg = get_config("qwen2.5-3b").reduced()
    model = build_model(cfg)
    params = model.init(rng)
    strat = LayerStrategy()
    plan = ExecutionPlan(arch="q", shape="t", mesh_axes=("data",), mesh_shape=(1,),
                         layer_strategies=[strat] * cfg.num_layers,
                         default_strategy=strat)
    eng = ServingEngine(model, plan, batch=2, max_len=24)
    prompt = jax.random.randint(rng, (2, 8), 0, cfg.vocab_size)
    out = eng.greedy_generate(params, prompt, max_new=6, max_len=24)
    assert out.shape == (2, 6)
    assert np.asarray(out).min() >= 0 and np.asarray(out).max() < cfg.vocab_size
    # greedy decode is deterministic
    out2 = eng.greedy_generate(params, prompt, max_new=6, max_len=24)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_search_scales_with_devices():
    """More devices must not slow the predicted step (weak scaling sanity)."""
    cfg = get_config("qwen3-14b")
    t = {}
    for shape in [(8, 16), (16, 16)]:
        res = SearchEngine(cfg).search(4096, 256, mesh_shape=shape,
                                       mesh_axes=("data", "model"), pp_options=[1])
        t[shape] = res.plan.predicted_step_time
    assert t[(16, 16)] <= t[(8, 16)] * 1.05
