"""Static plan verifier: one failing/passing plan pair per GALV code, the
PR-2 GPipe-OOM regression, and proof the search engine consults the verifier
(a violating candidate is rejected WITH its code and never costed)."""
import dataclasses

import pytest

from repro.analysis import invariants as inv
from repro.analysis import plan_check as pc
from repro.configs.registry import get_config
from repro.core import calibrate as cal_mod
from repro.core import profile_cache as pcache_mod
from repro.core import search as search_mod
from repro.core.cluster import TPU_V5E_POD
from repro.core.profiler_model import profile_model
from repro.core.search import SearchEngine
from repro.core.strategy import ExecutionPlan, LayerStrategy, uniform_plan

CFG = get_config("qwen3-14b")              # dense, 40 layers, 40 heads
SSM = get_config("mamba2-2.7b")
SEQ, BATCH = 4096, 256


def _mk(strat, shape, axes, cfg=CFG, **kw):
    return uniform_plan(cfg.name, "t", shape, axes, cfg.num_layers, strat, **kw)


def _check(plan, *, cfg=CFG, **kw):
    kw.setdefault("seq_len", SEQ)
    return pc.check_plan(plan, TPU_V5E_POD, cfg, **kw)


T1 = LayerStrategy()
T16 = LayerStrategy(tp=16)
POD = ("pod", "data", "model")

# (code, failing (plan, kwargs), passing twin (plan, kwargs)) — the twin is
# the minimal edit that clears exactly the exercised invariant
PAIRS = [
    ("GALV001",
     (_mk(T16, (32, 16), ("data", "model")), {}),            # 512 > 256 chips
     (_mk(T16, (16, 16), ("data", "model")), {})),
    ("GALV001",                                              # stage tiling
     (_mk(LayerStrategy(tp=3), (16, 16), ("data", "model")), {}),
     (_mk(LayerStrategy(tp=16), (16, 16), ("data", "model")), {})),
    ("GALV002",
     (_mk(T1, (16, 16), ("data",)), {}),                     # rank mismatch
     (_mk(T1, (16, 16), ("data", "model")), {})),
    ("GALV002",
     (_mk(T1, (16, 0), ("data", "model")), {}),              # zero-width axis
     (_mk(T1, (16, 1), ("data", "model")), {})),
    ("GALV003",
     (_mk(T16, (16, 16), ("data", "model"), pp=2, grad_accum=2), {}),
     (_mk(T16, (2, 8, 16), POD, pp=2, grad_accum=2), {})),
    ("GALV004",
     (dataclasses.replace(_mk(T1, (16, 16), ("data", "model")),
                          layer_strategies=[T1] * (CFG.num_layers - 1)), {}),
     (_mk(T1, (16, 16), ("data", "model")), {})),
    ("GALV005",
     (_mk(LayerStrategy(tp=4), (16, 16), ("data", "model")), {}),
     (_mk(LayerStrategy(tp=4), (64, 4), ("data", "model")), {})),
    ("GALV006",
     (_mk(LayerStrategy(ep=2), (16, 16), ("data", "model")), {}),  # dense
     (_mk(LayerStrategy(ep=2), (16, 16), ("data", "model"),
          cfg=get_config("grok-1-314b")),
      {"cfg": get_config("grok-1-314b")})),
    ("GALV010",
     (_mk(LayerStrategy(cp=4), (4, 4, 16), ("cp", "data", "model")),
      {"seq_len": SEQ - 6}),
     (_mk(LayerStrategy(cp=4), (4, 4, 16), ("cp", "data", "model")),
      {"seq_len": SEQ})),
    ("GALV011",
     (_mk(T16, (16, 16), ("data", "model")), {}),            # 40 heads, tp16
     (_mk(LayerStrategy(tp=8), (32, 8), ("data", "model")), {})),
    ("GALV012",
     (_mk(T1, (16, 16), ("data", "model")), {"global_batch": 8}),
     (_mk(T1, (16, 16), ("data", "model")), {"global_batch": BATCH})),
    ("GALV013",
     (_mk(T16, (16, 16), ("data", "model"), grad_accum=3),
      {"global_batch": BATCH}),
     (_mk(T16, (16, 16), ("data", "model"), grad_accum=4),
      {"global_batch": BATCH})),
    ("GALV014",
     (_mk(T16, (3, 4, 16), POD, pp=3, grad_accum=3), {}),    # 40 % 3 != 0
     (_mk(T16, (4, 4, 16), POD, pp=4, grad_accum=4), {})),
    ("GALV015",
     (_mk(T16, (2, 8, 16), POD, pp=2, grad_accum=3, pp_schedule="1f1b"), {}),
     (_mk(T16, (2, 8, 16), POD, pp=2, grad_accum=4, pp_schedule="1f1b"), {})),
    ("GALV015",
     (_mk(T16, (2, 8, 16), POD, pp=2, grad_accum=2,
          pp_schedule="interleaved", pp_interleave=3), {}),  # 40 % 6 != 0
     (_mk(T16, (2, 8, 16), POD, pp=2, grad_accum=2,
          pp_schedule="interleaved", pp_interleave=2), {})),
    ("GALV030",
     (dataclasses.replace(
         _mk(LayerStrategy(cp=2), (2, 16, 8), ("cp", "data", "model")),
         layer_strategies=[LayerStrategy(cp=2)] * 20
         + [LayerStrategy(cp=4)] * 20), {}),
     (_mk(LayerStrategy(cp=2), (2, 16, 8), ("cp", "data", "model")), {})),
    ("GALV031",
     (_mk(LayerStrategy(cp=4), (4, 4, 16), ("cp", "data", "model"), cfg=SSM),
      {"cfg": SSM}),
     (_mk(LayerStrategy(cp=4), (4, 4, 16), ("cp", "data", "model")), {})),
    ("GALV032",
     (_mk(LayerStrategy(cp=4), (4, 4, 16), ("data", "model", "x")), {}),
     (_mk(LayerStrategy(cp=4), (4, 4, 16), ("cp", "data", "model")), {})),
    ("GALV050",
     (_mk(T16, (16, 16), ("data", "model")),
      {"saved_plan": _mk(T16, (16, 16), ("data", "model"),
                         cfg=get_config("nemotron-4-15b"))}),
     (_mk(T16, (16, 16), ("data", "model")),
      {"saved_plan": _mk(T16, (8, 8), ("data", "model"))})),  # mesh may differ
    ("GALV070",
     (dataclasses.replace(_mk(T1, (16, 16), ("data", "model")),
                          predicted_step_time=0.1),
      {"measured_step_time": 0.25}),                     # 2.5x the prediction
     (dataclasses.replace(_mk(T1, (16, 16), ("data", "model")),
                          predicted_step_time=0.1),
      {"measured_step_time": 0.15})),
    ("GALV060",
     (_mk(T1, (16, 16), ("data", "model")),
      {"calibration": cal_mod.Calibration(
          source="measured",
          provenance={"cache_schema": pcache_mod.SCHEMA_VERSION - 1})}),
     (_mk(T1, (16, 16), ("data", "model")),
      {"calibration": cal_mod.Calibration(
          source="measured",
          provenance={"cache_schema": pcache_mod.SCHEMA_VERSION})})),
    ("GALV080",                        # 4096 % 48 != 0: partial tail page
     (_mk(T1, (16, 16), ("data", "model")),
      {"serve": pc.ServeSpec(num_slots=8, page_size=48, max_context=4096,
                             tp=16)}),
     (_mk(T1, (16, 16), ("data", "model")),
      {"serve": pc.ServeSpec(num_slots=8, page_size=64, max_context=4096,
                             tp=16)})),
    ("GALV081",                        # 14B bf16 weights alone blow 16 GB
     (_mk(T1, (16, 16), ("data", "model")),
      {"serve": pc.ServeSpec(num_slots=8, page_size=64, max_context=4096,
                             tp=1)}),
     (_mk(T1, (16, 16), ("data", "model")),
      {"serve": pc.ServeSpec(num_slots=8, page_size=64, max_context=4096,
                             tp=16)})),
    ("GALV082",                        # 3 real pages for 8 decode slots
     (_mk(T1, (16, 16), ("data", "model")),
      {"serve": pc.ServeSpec(num_slots=8, page_size=64, max_context=4096,
                             num_pages=4, tp=16)}),
     (_mk(T1, (16, 16), ("data", "model")),
      {"serve": pc.ServeSpec(num_slots=8, page_size=64, max_context=4096,
                             tp=16)})),
]


@pytest.mark.parametrize("code,bad,good", PAIRS,
                         ids=[f"{c}-{i}" for i, (c, _, _) in enumerate(PAIRS)])
def test_code_pair(code, bad, good):
    bad_plan, bad_kw = bad
    good_plan, good_kw = good
    bad_cfg = bad_kw.pop("cfg", CFG)
    good_cfg = good_kw.pop("cfg", CFG)
    assert code in _check(bad_plan, cfg=bad_cfg, **bad_kw).codes()
    assert code not in _check(good_plan, cfg=good_cfg, **good_kw).codes()


def test_diagnostics_carry_severity_and_hint():
    rep = _check(_mk(T16, (32, 16), ("data", "model")))
    d = next(d for d in rep.diagnostics if d.code == "GALV001")
    assert d.severity == "error" and d.hint and d.slug == "mesh-overcommit"
    # GALV011 is a warning: it degrades, it does not reject
    rep11 = _check(_mk(T16, (16, 16), ("data", "model")))
    assert rep11.codes() == ["GALV011"] and rep11.ok()


def test_format_table_renders_codes_and_status():
    rep = _check(_mk(T16, (16, 16), ("data", "model"), grad_accum=3),
                 global_batch=BATCH)
    table = rep.format_table()
    assert "GALV013" in table and "hint:" in table and "FAIL" in table
    assert "OK (0 diagnostics)" in _check(
        _mk(T1, (16, 16), ("data", "model"))).format_table()


def test_cost_model_drift_is_a_two_sided_warning():
    """GALV070 fires in either direction (a cost model that *overestimates*
    by 2x is as stale as one that underestimates) and is advisory — a
    drifting plan still verifies ok() so a live run is never invalidated."""
    plan = dataclasses.replace(_mk(T1, (16, 16), ("data", "model")),
                               predicted_step_time=0.1)
    slow = _check(plan, measured_step_time=0.5)
    fast = _check(plan, measured_step_time=0.01)
    assert "GALV070" in slow.codes() and "GALV070" in fast.codes()
    assert slow.ok() and fast.ok()                       # warning, not error
    d = next(d for d in slow.diagnostics if d.code == "GALV070")
    assert d.severity == "warning" and d.slug == "cost-model-drift"
    # no prediction (or no measurement) -> nothing to compare, no diagnostic
    zero = dataclasses.replace(plan, predicted_step_time=0.0)
    assert "GALV070" not in _check(zero, measured_step_time=0.5).codes()
    assert "GALV070" not in _check(plan).codes()


def test_mesh_malformed_short_circuits():
    """A malformed mesh makes every downstream width lookup meaningless —
    GALV002 must be the only diagnostic."""
    rep = _check(_mk(LayerStrategy(cp=4), (16,), ("cp", "data", "model"),
                     pp=2, grad_accum=3))
    assert rep.error_codes() == ["GALV002"]


# ------------------------------------------------------------- GALV020/040

def test_pr2_gpipe_oom_shape_rejected():
    """Regression for the PR 2 OOM class: ga=32 × pp=4 under gpipe keeps all
    32 microbatch activations in flight and blows the 16 GB HBM; the same
    plan under 1f1b (min(pp, M) in flight) fits.  The verifier must tell
    them apart statically."""
    profile = profile_model(CFG, SEQ)
    strat = LayerStrategy(tp=16, zero=3, remat="full")
    bad = _mk(strat, (4, 4, 16), POD, pp=4, grad_accum=32,
              pp_schedule="gpipe")
    rep = _check(bad, global_batch=BATCH, profile=profile)
    assert rep.error_codes() == ["GALV020"]
    good = dataclasses.replace(bad, pp_schedule="1f1b")
    assert _check(good, global_batch=BATCH, profile=profile).ok()


def test_boundary_dtype_mismatch_detected(monkeypatch):
    """GALV040: the cost model's boundary bytes/elem and the runtime's
    boundary dtype are checked against each other — drifting either one
    without the other is caught before anything compiles."""
    plan = _mk(T16, (2, 8, 16), POD, pp=2, grad_accum=2)
    assert "GALV040" not in _check(plan).codes()
    from repro.core import cost_model as cm

    monkeypatch.setattr(cm, "PIPELINE_BOUNDARY_BYTES_PER_ELEM", 2.0)
    assert "GALV040" in _check(plan).error_codes()


def test_cost_model_uses_the_shared_constant():
    from repro.core import cost_model as cm
    from repro.core.profiler_model import profile_model as pm

    env = cm.CostEnv(cluster=TPU_V5E_POD, devices=16, pp=2, micro_batch=8,
                     grad_accum=2)
    profile = pm(CFG, 512)
    base = cm.pipeline_boundary_bytes(profile, env, T1)
    assert base == pytest.approx(
        profile.d_model * profile.seq_len * env.micro_batch / 16
        * cm.PIPELINE_BOUNDARY_BYTES_PER_ELEM)


# ------------------------------------------------- search engine integration

def test_search_rejects_injected_candidate_with_code_and_never_costs_it(
        monkeypatch):
    """The acceptance gate: inject a GALV010-violating candidate (cp=2 with
    seq % (2·cp) != 0) into the candidate set and prove the search rejects
    it WITH the code — the cost model never sees it."""
    cfg = get_config("llama3.2-1b")
    eng = SearchEngine(cfg)
    seq = 126                                # 126 % 4 != 0 -> cp=2 invalid
    profile = eng._profile(seq)
    bad = LayerStrategy(cp=2)
    good = LayerStrategy(zero=3, remat="full")
    costed = []
    orig = search_mod.cm.layer_step_time
    monkeypatch.setattr(search_mod.cm, "layer_step_time",
                        lambda lp, s, env: costed.append(s) or orig(lp, s, env))
    rejections = {}
    plan = eng._evaluate(profile, [good, bad], 8, 1, 1, 8,
                         ("data",), (8,), 1024, arch=cfg.name, shape_name="t",
                         rejections=rejections)
    assert rejections.get("GALV010") == 1
    assert bad not in costed and good in costed
    assert plan is not None and all(s.cp == 1 for s in plan.layer_strategies)


def test_search_result_reports_rejections():
    res = SearchEngine(CFG).search(SEQ, BATCH, mesh_shape=(16, 16),
                                   mesh_axes=("data", "model"),
                                   pp_options=[1])
    assert res.feasible
    assert res.rejections and all(c in pc.CATALOG for c in res.rejections)


def test_searched_plan_verifies_clean():
    cfg = get_config("llama3.2-1b")
    res = SearchEngine(cfg).search(1024, 64, mesh_shape=(4, 4),
                                   mesh_axes=("data", "model"),
                                   pp_options=[1])
    assert res.feasible
    rep = pc.check_plan(res.plan, TPU_V5E_POD, cfg, seq_len=1024,
                        global_batch=64, profile=profile_model(cfg, 1024))
    assert rep.ok(), rep.format_table()


def test_replan_produces_verified_plan():
    from repro.runtime.elastic import ElasticEvent, replan

    cfg = get_config("llama3.2-1b")
    plan = replan(cfg, ElasticEvent(old_devices=8, new_devices=6),
                  seq_len=256, global_batch=12)
    sub = dataclasses.replace(TPU_V5E_POD, chips=plan.num_devices)
    assert pc.check_plan(plan, sub, cfg, seq_len=256,
                         global_batch=12).ok()


# ------------------------------------------------------ shared predicates

def test_invariants_predicates():
    assert inv.cp_seq_divisible(4096, 4) and not inv.cp_seq_divisible(4090, 4)
    assert inv.cp_seq_divisible(7, 1)            # cp=1 never constrains
    assert inv.pp_layers_divisible(40, 4) and not inv.pp_layers_divisible(40, 3)
    assert inv.batch_shardable(256, 16) and not inv.batch_shardable(8, 3)
    assert inv.ga_divides_batch(256, 32) and not inv.ga_divides_batch(256, 3)
    assert inv.mesh_factorizable(256, 16, 1) == (True, 16)
    assert inv.mesh_factorizable(256, 3, 1)[0] is False
    assert inv.heads_shardable(40, 8) and not inv.heads_shardable(40, 16)
    assert inv.experts_shardable(64, 8, 16)
    assert not inv.experts_shardable(64, 8, 4)   # ep > dp
    assert not inv.experts_shardable(0, 2, 16)   # no experts to shard


def test_unknown_code_rejected():
    with pytest.raises(ValueError):
        pc.Diagnostic("GALV999", "nope")


# ------------------------------------------ compiled-artifact audit (GALV09x)
# Failing/passing twins for the codes the compiled-artifact auditor
# (repro.analysis.hlo_audit / jaxpr_audit) emits, over synthetic post-SPMD
# HLO text and tiny staged jaxprs.  The full-runtime planted-defect corpus
# (real compiled steps) lives in benchmarks/hlo_audit.py.

from repro.analysis import hlo_audit as ha  # noqa: E402

AUDIT_CFG = get_config("llama3.2-1b").reduced()
AUDIT_SEQ, AUDIT_BATCH = 64, 8


def _audit_plan(**kw):
    kw.setdefault("zero", 0)
    return uniform_plan(AUDIT_CFG.name, "t", (4, 1), ("data", "model"),
                        AUDIT_CFG.num_layers, LayerStrategy(**kw))


def _audit(plan, hlo=None, jaxpr=None):
    return ha.audit_step(plan, AUDIT_CFG, seq_len=AUDIT_SEQ,
                         global_batch=AUDIT_BATCH, hlo_text=hlo, jaxpr=jaxpr)


def _hlo(*body_lines):
    body = "\n".join(f"  {ln}" for ln in body_lines)
    return ("HloModule jit_step\n\nENTRY %main () -> f32[8] {\n" + body
            + "\n  ROOT %out = f32[8]{0} copy(%x)\n}\n")


def _matched_data_ar(plan):
    """An all-reduce line over the (4,1) data axis sized exactly to the
    census prediction, so the twin HLO sits inside the GALV090 band."""
    pred = _audit(plan, hlo=_hlo()).predicted
    data_bytes = sum(e.bytes for e in pred if e.axis == "data")
    n = max(int(data_bytes // 4), 1)
    return (f"%ar = f32[{n}]{{0}} all-reduce(%p), "
            "replica_groups={{0,1,2,3}}, to_apply=%add")


def test_galv090_comm_mismatch_pair():
    """GALV090: >256 KB of all-gather traffic on an axis where the plan
    predicts none is a silent GSPMD reshard — always an error; the same HLO
    without the gather (grad all-reduce matching the census) audits clean."""
    plan = _audit_plan()
    ar = _matched_data_ar(plan)
    bad = _hlo(ar,
               "%ag = f32[400000]{0} all-gather(%p2), "
               "replica_groups={{0,1,2,3}}, dimensions={0}")
    rep = _audit(plan, hlo=bad)
    assert "GALV090" in rep.error_codes(), rep.format_table()
    good = _audit(plan, hlo=_hlo(ar))
    assert "GALV090" not in good.codes(), good.format_table()
    assert good.ok() and not good.codes()


def test_galv091_dtype_drift_pair():
    import jax
    import jax.numpy as jnp

    plan = _audit_plan()
    x32 = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    x16 = jax.ShapeDtypeStruct((8, 8), jnp.bfloat16)
    bad = _audit(plan, jaxpr=jax.make_jaxpr(lambda x: x @ x)(x32))
    assert "GALV091" in bad.error_codes()
    good = _audit(plan, jaxpr=jax.make_jaxpr(lambda x: x @ x)(x16))
    assert "GALV091" not in good.codes() and good.ok()


def test_galv092_remat_missing_pair():
    import jax
    import jax.numpy as jnp

    plan = _audit_plan(remat="selective")
    x = jax.ShapeDtypeStruct((8, 8), jnp.bfloat16)
    bad = _audit(plan, jaxpr=jax.make_jaxpr(lambda a: a @ a)(x))
    assert "GALV092" in bad.error_codes()        # declared but not staged
    good = _audit(plan, jaxpr=jax.make_jaxpr(
        jax.checkpoint(lambda a: a @ a))(x))     # dot inside the remat region
    assert "GALV092" not in good.codes() and good.ok()
    # a remat='none' plan never demands checkpoint regions
    none = _audit(_audit_plan(), jaxpr=jax.make_jaxpr(lambda a: a @ a)(x))
    assert "GALV092" not in none.codes()


def test_galv093_host_callback_pair():
    import jax
    import jax.numpy as jnp

    plan = _audit_plan()
    x = jax.ShapeDtypeStruct((8, 8), jnp.bfloat16)

    def noisy(a):
        jax.debug.print("step {x}", x=a.sum())
        return a @ a

    bad = _audit(plan, jaxpr=jax.make_jaxpr(noisy)(x))
    assert "GALV093" in bad.error_codes()        # jaxpr side: debug_callback
    hlo_bad = _audit(plan, hlo=_hlo(
        _matched_data_ar(plan),
        '%cc = f32[8]{0} custom-call(%x), '
        'custom_call_target="xla_ffi_python_cpu_callback"'))
    assert "GALV093" in hlo_bad.error_codes()    # HLO side: host custom-call
    good = _audit(plan, jaxpr=jax.make_jaxpr(lambda a: a @ a)(x))
    assert "GALV093" not in good.codes() and good.ok()


def _hlo_with_while(cond_body_line):
    return f"""
HloModule jit_step

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {{
  %p = (s32[], f32[8]{{0}}) parameter(0)
  %ar = f32[8]{{0}} all-reduce(%gte), replica_groups={{{{0,1,2,3}}}}, to_apply=%add
  ROOT %t = (s32[], f32[8]{{0}}) tuple(%c, %ar)
}}

%cond (p.1: (s32[], f32[8])) -> pred[] {{
  %p.1 = (s32[], f32[8]{{0}}) parameter(0)
  {cond_body_line}
  ROOT %cmp = pred[] compare(%i, %lim), direction=LT
}}

ENTRY %main () -> f32[8] {{
  %init = (s32[], f32[8]{{0}}) tuple(%zero, %zeros)
  %w = (s32[], f32[8]{{0}}) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8]{{0}} get-tuple-element(%w), index=1
}}
"""


def test_galv094_scan_undercount_pair():
    """GALV094: a while loop whose trip count cannot be recovered makes the
    byte census unverifiable — warn and SKIP the GALV090 band comparison
    (an undercounted census must not masquerade as a mismatch)."""
    plan = _audit_plan()
    bad = _audit(plan, hlo=_hlo_with_while(
        "%lim = s32[] get-tuple-element(%p.1), index=0"))   # data-dependent
    assert "GALV094" in bad.codes()
    assert bad.ok()                                  # warning, not rejection
    assert "GALV090" not in bad.codes()              # band comparison skipped
    good = _audit(plan, hlo=_hlo_with_while("%lim = s32[] constant(10)"))
    assert "GALV094" not in good.codes()
    assert "GALV090" in good.codes()                 # band check ran instead
