"""Checkpoint: atomic save/restore roundtrip, GC, elastic replan + regroup."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.strategy import ExecutionPlan, LayerStrategy
from repro.models import build_model
from repro.runtime import checkpoint as ckpt
from repro.runtime.data import SyntheticDataset
from repro.runtime.elastic import ElasticEvent, replan, surviving_mesh
from repro.runtime.train import construct_hybrid_parallel_model


def _setup(rng):
    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    strat = LayerStrategy()
    plan = ExecutionPlan(arch="llama3.2-1b", shape="t", mesh_axes=("data",),
                         mesh_shape=(1,), layer_strategies=[strat] * cfg.num_layers,
                         default_strategy=strat)
    hp = construct_hybrid_parallel_model(model, plan)
    return cfg, model, plan, hp


def test_roundtrip(tmp_path, rng):
    cfg, model, plan, hp = _setup(rng)
    params = hp.init_params(rng)
    opt = hp.init_opt_state(params)
    ckpt.save(tmp_path, 7, hp.ungroup(params), opt, plan)
    assert ckpt.latest_step(tmp_path) == 7
    out = ckpt.restore(tmp_path, params_like=hp.ungroup(params), opt_like=opt)
    assert out["step"] == 7
    for a, b in zip(jax.tree.leaves(hp.ungroup(params)), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert out["plan"].layer_strategies == plan.layer_strategies


def test_gc_keeps_latest(tmp_path, rng):
    cfg, model, plan, hp = _setup(rng)
    params = hp.ungroup(hp.init_params(rng))
    for step in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, step, params, None, plan, keep=2)
    steps = sorted(int(p.stem[4:]) for p in tmp_path.glob("step*.ckpt"))
    assert steps == [4, 5]
    assert ckpt.latest_step(tmp_path) == 5


def test_elastic_replan_and_resume(tmp_path, rng):
    """Save under plan A, lose devices, re-search plan B, restore + step."""
    cfg, model, plan, hp = _setup(rng)
    params = hp.init_params(rng)
    opt = hp.init_opt_state(params)
    ds = SyntheticDataset(cfg, seq_len=16, global_batch=2)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    params, opt, m0 = hp.jit_train_step(donate=False)(params, opt, batch)
    ckpt.save(tmp_path, 1, hp.ungroup(params), None, plan)

    event = ElasticEvent(old_devices=256, new_devices=192)
    new_plan = replan(get_config("llama3.2-1b"), event, 4096, 256)
    assert new_plan.num_devices <= 192
    assert "elastic replan" in new_plan.notes

    # restore the canonical params and regroup for a (heterogeneous) new plan
    strats = ([LayerStrategy(remat="selective")] * (cfg.num_layers // 2)
              + [LayerStrategy()] * (cfg.num_layers - cfg.num_layers // 2))
    plan_b = ExecutionPlan(arch="llama3.2-1b", shape="t", mesh_axes=("data",),
                           mesh_shape=(1,), layer_strategies=strats,
                           default_strategy=strats[0])
    hp_b = construct_hybrid_parallel_model(model, plan_b)
    restored = ckpt.restore(tmp_path, params_like=hp.ungroup(params))["params"]
    params_b = hp_b.group(jax.tree.map(jnp.asarray, restored))
    opt_b = hp_b.init_opt_state(params_b)
    _, _, m1 = hp_b.jit_train_step(donate=False)(params_b, opt_b, batch)
    assert np.isfinite(float(m1["loss"]))
    # same weights, same batch => same loss across plans
    np.testing.assert_allclose(float(m1["loss"]), float(
        hp.jit_train_step(donate=False)(params, opt, batch)[2]["loss"]), rtol=0.2)


def test_surviving_mesh_shapes():
    assert surviving_mesh(256) == ((16, 16), ("data", "model"))
    # 192 survivors form an exact (12, 16) rectangle — the old power-of-two
    # shrink planned (8, 16) and idled 64 chips (see test_elastic_resize.py)
    assert surviving_mesh(192) == ((12, 16), ("data", "model"))
    assert surviving_mesh(192, global_batch=256) == ((8, 16), ("data", "model"))
    assert surviving_mesh(8, model_axis=16) == ((1, 8), ("data", "model"))


def test_restore_device_puts_params_and_opt_onto_shardings(tmp_path, rng):
    """restore(shardings=..., opt_shardings=...) places leaves directly onto
    the target mesh — the manual-reshard API the elastic flow documents
    (the trainers' place_* hooks are the usual path, so pin this one here)."""
    from repro.compat import NamedSharding, P, make_mesh

    cfg, model, plan, hp = _setup(rng)
    params = hp.ungroup(hp.init_params(rng))
    opt = hp.init_opt_state(params)
    ckpt.save(tmp_path, 2, params, opt, plan)
    mesh = make_mesh((1,), ("data",))
    repl = lambda tree: jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    out = ckpt.restore(tmp_path, params_like=params, opt_like=opt,
                       shardings=repl(params), opt_shardings=repl(opt))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for tree in (out["params"], out["opt"]):
        for leaf in jax.tree.leaves(tree):
            assert isinstance(leaf.sharding, NamedSharding)


# ------------------------------------------------------------ codec registry

def _tiny_payload():
    return {"params/w": {"dtype": "float32", "shape": [2, 2],
                         "data": np.arange(4, dtype=np.float32).tobytes()}}


def test_codec_auto_selection_prefers_available():
    from repro.runtime import compression as comp

    codec = comp.best_codec()
    if comp._zstd_available():
        assert codec.name == "zstd"
    else:
        assert codec.name == "zlib"   # stdlib fallback, never raw


def test_blob_roundtrip_every_available_codec():
    from repro.runtime import compression as comp

    payload = _tiny_payload()
    for codec in comp.CHECKPOINT_CODECS:
        if not codec.available():
            continue
        blob = ckpt.encode_blob(payload, codec=codec.name)
        assert blob[:4] == ckpt.MAGIC
        assert blob[5] == codec.fmt_byte
        back = ckpt.decode_blob(blob)
        assert back["params/w"]["dtype"] == "float32"
        assert bytes(back["params/w"]["data"]) == payload["params/w"]["data"]


def test_blob_header_records_codec_byte_for_cross_env_restore(monkeypatch):
    """A zlib-written file must restore even where zstd IS available (the
    header byte, not the environment, picks the decompressor) — and the
    auto-selected writer must degrade to zlib when zstd is missing."""
    from repro.runtime import compression as comp

    blob = ckpt.encode_blob(_tiny_payload(), codec="zlib")
    assert blob[5] == comp.get_codec("zlib").fmt_byte
    assert ckpt.decode_blob(blob)["params/w"]["shape"] == [2, 2]

    monkeypatch.setitem(comp._BY_NAME, "zstd", comp.CheckpointCodec(
        "zstd", 2, lambda: False, comp._zstd_compress, comp._zstd_decompress))
    monkeypatch.setattr(comp, "CHECKPOINT_CODECS", tuple(
        comp._BY_NAME[n] for n in ("zstd", "zlib", "raw")))
    assert comp.best_codec().name == "zlib"


def test_unknown_codec_errors():
    import pytest

    from repro.runtime import compression as comp

    with pytest.raises(KeyError):
        comp.get_codec("lz4")
    with pytest.raises(ValueError):
        comp.codec_for_byte(250)


def test_save_restore_roundtrip_with_explicit_codec(tmp_path, rng):
    cfg, model, plan, hp = _setup(rng)
    params = hp.ungroup(hp.init_params(rng))
    ckpt.save(tmp_path, 3, params, None, plan, codec="raw")
    blob = (tmp_path / "step000000003.ckpt").read_bytes()
    assert blob[:4] == ckpt.MAGIC and blob[5] == 0       # raw format byte
    out = ckpt.restore(tmp_path, params_like=params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
