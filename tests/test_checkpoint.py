"""Checkpoint: atomic save/restore roundtrip, GC, elastic replan + regroup."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.strategy import ExecutionPlan, LayerStrategy
from repro.models import build_model
from repro.runtime import checkpoint as ckpt
from repro.runtime.data import SyntheticDataset
from repro.runtime.elastic import ElasticEvent, replan, surviving_mesh
from repro.runtime.train import construct_hybrid_parallel_model


def _setup(rng):
    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    strat = LayerStrategy()
    plan = ExecutionPlan(arch="llama3.2-1b", shape="t", mesh_axes=("data",),
                         mesh_shape=(1,), layer_strategies=[strat] * cfg.num_layers,
                         default_strategy=strat)
    hp = construct_hybrid_parallel_model(model, plan)
    return cfg, model, plan, hp


def test_roundtrip(tmp_path, rng):
    cfg, model, plan, hp = _setup(rng)
    params = hp.init_params(rng)
    opt = hp.init_opt_state(params)
    ckpt.save(tmp_path, 7, hp.ungroup(params), opt, plan)
    assert ckpt.latest_step(tmp_path) == 7
    out = ckpt.restore(tmp_path, params_like=hp.ungroup(params), opt_like=opt)
    assert out["step"] == 7
    for a, b in zip(jax.tree.leaves(hp.ungroup(params)), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert out["plan"].layer_strategies == plan.layer_strategies


def test_gc_keeps_latest(tmp_path, rng):
    cfg, model, plan, hp = _setup(rng)
    params = hp.ungroup(hp.init_params(rng))
    for step in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, step, params, None, plan, keep=2)
    steps = sorted(int(p.stem[4:]) for p in tmp_path.glob("step*.json"))
    assert steps == [4, 5]
    assert ckpt.latest_step(tmp_path) == 5


def test_gc_keeps_latest_v1_layout(tmp_path, rng):
    """GC retention is format-agnostic: v1 single-file steps age out too."""
    cfg, model, plan, hp = _setup(rng)
    params = hp.ungroup(hp.init_params(rng))
    for step in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, step, params, None, plan, keep=2, version=1)
    steps = sorted(int(p.stem[4:]) for p in tmp_path.glob("step*.ckpt"))
    assert steps == [4, 5]
    assert ckpt.latest_step(tmp_path) == 5


def test_elastic_replan_and_resume(tmp_path, rng):
    """Save under plan A, lose devices, re-search plan B, restore + step."""
    cfg, model, plan, hp = _setup(rng)
    params = hp.init_params(rng)
    opt = hp.init_opt_state(params)
    ds = SyntheticDataset(cfg, seq_len=16, global_batch=2)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    params, opt, m0 = hp.jit_train_step(donate=False)(params, opt, batch)
    ckpt.save(tmp_path, 1, hp.ungroup(params), None, plan)

    event = ElasticEvent(old_devices=256, new_devices=192)
    new_plan = replan(get_config("llama3.2-1b"), event, 4096, 256)
    assert new_plan.num_devices <= 192
    assert "elastic replan" in new_plan.notes

    # restore the canonical params and regroup for a (heterogeneous) new plan
    strats = ([LayerStrategy(remat="selective")] * (cfg.num_layers // 2)
              + [LayerStrategy()] * (cfg.num_layers - cfg.num_layers // 2))
    plan_b = ExecutionPlan(arch="llama3.2-1b", shape="t", mesh_axes=("data",),
                           mesh_shape=(1,), layer_strategies=strats,
                           default_strategy=strats[0])
    hp_b = construct_hybrid_parallel_model(model, plan_b)
    restored = ckpt.restore(tmp_path, params_like=hp.ungroup(params))["params"]
    params_b = hp_b.group(jax.tree.map(jnp.asarray, restored))
    opt_b = hp_b.init_opt_state(params_b)
    _, _, m1 = hp_b.jit_train_step(donate=False)(params_b, opt_b, batch)
    assert np.isfinite(float(m1["loss"]))
    # same weights, same batch => same loss across plans
    np.testing.assert_allclose(float(m1["loss"]), float(
        hp.jit_train_step(donate=False)(params, opt, batch)[2]["loss"]), rtol=0.2)


def test_surviving_mesh_shapes():
    assert surviving_mesh(256) == ((16, 16), ("data", "model"))
    # 192 survivors form an exact (12, 16) rectangle — the old power-of-two
    # shrink planned (8, 16) and idled 64 chips (see test_elastic_resize.py)
    assert surviving_mesh(192) == ((12, 16), ("data", "model"))
    assert surviving_mesh(192, global_batch=256) == ((8, 16), ("data", "model"))
    assert surviving_mesh(8, model_axis=16) == ((1, 8), ("data", "model"))


def test_restore_device_puts_params_and_opt_onto_shardings(tmp_path, rng):
    """restore(shardings=..., opt_shardings=...) places leaves directly onto
    the target mesh — the manual-reshard API the elastic flow documents
    (the trainers' place_* hooks are the usual path, so pin this one here)."""
    from repro.compat import NamedSharding, P, make_mesh

    cfg, model, plan, hp = _setup(rng)
    params = hp.ungroup(hp.init_params(rng))
    opt = hp.init_opt_state(params)
    ckpt.save(tmp_path, 2, params, opt, plan)
    mesh = make_mesh((1,), ("data",))
    repl = lambda tree: jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    out = ckpt.restore(tmp_path, params_like=params, opt_like=opt,
                       shardings=repl(params), opt_shardings=repl(opt))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for tree in (out["params"], out["opt"]):
        for leaf in jax.tree.leaves(tree):
            assert isinstance(leaf.sharding, NamedSharding)


# ------------------------------------------------------------ codec registry

def _tiny_payload():
    return {"params/w": {"dtype": "float32", "shape": [2, 2],
                         "data": np.arange(4, dtype=np.float32).tobytes()}}


def test_codec_auto_selection_prefers_available():
    from repro.runtime import compression as comp

    codec = comp.best_codec()
    if comp._zstd_available():
        assert codec.name == "zstd"
    else:
        assert codec.name == "zlib"   # stdlib fallback, never raw


def test_blob_roundtrip_every_available_codec():
    from repro.runtime import compression as comp

    payload = _tiny_payload()
    for codec in comp.CHECKPOINT_CODECS:
        if not codec.available():
            continue
        blob = ckpt.encode_blob(payload, codec=codec.name)
        assert blob[:4] == ckpt.MAGIC
        assert blob[5] == codec.fmt_byte
        back = ckpt.decode_blob(blob)
        assert back["params/w"]["dtype"] == "float32"
        assert bytes(back["params/w"]["data"]) == payload["params/w"]["data"]


def test_blob_header_records_codec_byte_for_cross_env_restore(monkeypatch):
    """A zlib-written file must restore even where zstd IS available (the
    header byte, not the environment, picks the decompressor) — and the
    auto-selected writer must degrade to zlib when zstd is missing."""
    from repro.runtime import compression as comp

    blob = ckpt.encode_blob(_tiny_payload(), codec="zlib")
    assert blob[5] == comp.get_codec("zlib").fmt_byte
    assert ckpt.decode_blob(blob)["params/w"]["shape"] == [2, 2]

    monkeypatch.setitem(comp._BY_NAME, "zstd", comp.CheckpointCodec(
        "zstd", 2, lambda: False, comp._zstd_compress, comp._zstd_decompress))
    monkeypatch.setattr(comp, "CHECKPOINT_CODECS", tuple(
        comp._BY_NAME[n] for n in ("zstd", "zlib", "raw")))
    assert comp.best_codec().name == "zlib"


def test_unknown_codec_errors():
    import pytest

    from repro.runtime import compression as comp

    with pytest.raises(KeyError):
        comp.get_codec("lz4")
    with pytest.raises(ValueError):
        comp.codec_for_byte(250)


def test_save_restore_roundtrip_with_explicit_codec(tmp_path, rng):
    cfg, model, plan, hp = _setup(rng)
    params = hp.ungroup(hp.init_params(rng))
    ckpt.save(tmp_path, 3, params, None, plan, codec="raw", version=1)
    blob = (tmp_path / "step000000003.ckpt").read_bytes()
    assert blob[:4] == ckpt.MAGIC and blob[5] == 0       # raw format byte
    out = ckpt.restore(tmp_path, params_like=params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # v2 shard blobs carry the same header discipline
    ckpt.save(tmp_path, 4, params, None, plan, codec="raw")
    shard = next((tmp_path / "blobs").glob("*.gvck")).read_bytes()
    assert shard[:4] == ckpt.MAGIC
    assert shard[4] == ckpt.FORMAT_V2 and shard[5] == 0
    out = ckpt.restore(tmp_path, 4, params_like=params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- v2 sharded format

def _blob_names(directory):
    return {p.name for p in (directory / "blobs").glob("*.gvck")}


def _physical_blob_bytes(directory):
    return sum(p.stat().st_size for p in (directory / "blobs").glob("*.gvck"))


def test_v2_shard_roundtrip_and_layout(tmp_path, rng):
    """Default save writes the sharded layout: blobs/ + step index, no
    monolithic .ckpt file; restore rebuilds every leaf bitwise."""
    cfg, model, plan, hp = _setup(rng)
    params = hp.ungroup(hp.init_params(rng))
    opt = hp.init_opt_state(hp.group(params))
    path = ckpt.save(tmp_path, 11, params, opt, plan)
    assert path.name == "step000000011.json"
    assert not list(tmp_path.glob("*.ckpt"))
    assert _blob_names(tmp_path)
    out = ckpt.restore(tmp_path, params_like=params, opt_like=opt)
    assert out["step"] == 11
    for a, b in zip(jax.tree.leaves((params, opt)),
                    jax.tree.leaves((out["params"], out["opt"]))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_v2_dedup_repeated_saves_share_blobs(tmp_path, rng):
    """Unchanged leaves cost zero new bytes: a second save of the same state
    adds only an index file, and a partially-changed save adds only the
    changed leaves' blobs."""
    cfg, model, plan, hp = _setup(rng)
    params = hp.ungroup(hp.init_params(rng))
    ckpt.save(tmp_path, 1, params, None, plan, keep=10)
    blobs_1 = _blob_names(tmp_path)
    bytes_1 = _physical_blob_bytes(tmp_path)
    ckpt.save(tmp_path, 2, params, None, plan, keep=10)
    assert _blob_names(tmp_path) == blobs_1          # zero new blobs
    assert _physical_blob_bytes(tmp_path) == bytes_1

    mutated = dict(params)
    mutated["final_norm"] = jax.tree.map(lambda x: x + 1.0, params["final_norm"])
    ckpt.save(tmp_path, 3, mutated, None, plan, keep=10)
    added = _blob_names(tmp_path) - blobs_1
    changed_leaves = len(jax.tree.leaves(params["final_norm"]))
    assert 0 < len(added) <= changed_leaves
    out = ckpt.restore(tmp_path, 3, params_like=mutated)
    for a, b in zip(jax.tree.leaves(mutated), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_v2_refcount_gc_shared_blob_survives(tmp_path, rng):
    """A blob shared by several step indexes survives GC until the LAST
    referencing step is dropped — then the orphan is collected."""
    cfg, model, plan, hp = _setup(rng)
    params = hp.ungroup(hp.init_params(rng))
    for step in (1, 2, 3):
        ckpt.save(tmp_path, step, params, None, plan, keep=2)
    shared = _blob_names(tmp_path)
    assert sorted(int(p.stem[4:]) for p in tmp_path.glob("step*.json")) == [2, 3]
    assert _blob_names(tmp_path) == shared           # still referenced by 2,3

    other = jax.tree.map(lambda x: x * 2.0 + 1.0, params)
    ckpt.save(tmp_path, 4, other, None, plan, keep=2)   # drops step 2
    assert _blob_names(tmp_path) >= shared           # step 3 still refs them
    ckpt.save(tmp_path, 5, other, None, plan, keep=2)   # drops step 3
    assert not (_blob_names(tmp_path) & shared), \
        "orphaned blobs must be collected once no index references them"
    out = ckpt.restore(tmp_path, 5, params_like=other)
    for a, b in zip(jax.tree.leaves(other), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_read_compat_matrix(tmp_path, rng):
    """v2 (current), v1 (single-file), and legacy (pre-header zstd+msgpack)
    checkpoints all restore to identical arrays."""
    cfg, model, plan, hp = _setup(rng)
    params = hp.ungroup(hp.init_params(rng))
    want = [np.asarray(x) for x in jax.tree.leaves(params)]

    def assert_restores(directory):
        out = ckpt.restore(directory, params_like=params)
        for a, b in zip(want, jax.tree.leaves(out["params"])):
            np.testing.assert_array_equal(a, np.asarray(b))

    v2 = tmp_path / "v2"
    ckpt.save(v2, 1, params, None, plan)
    assert_restores(v2)

    v1 = tmp_path / "v1"
    ckpt.save(v1, 1, params, None, plan, version=1)
    assert (v1 / "step000000001.ckpt").exists()
    assert_restores(v1)

    from repro.runtime.compression import _zstd_available
    if not (_zstd_available() and ckpt._have_msgpack()):
        import pytest
        pytest.skip("legacy framing needs zstandard+msgpack")
    import msgpack
    import zstandard
    legacy = tmp_path / "legacy"
    legacy.mkdir()
    payload = {f"params/{k}": {"dtype": str(np.asarray(v).dtype),
                               "shape": list(np.asarray(v).shape),
                               "data": np.asarray(v).tobytes()}
               for k, v in ckpt._flatten(params).items()}
    blob = zstandard.ZstdCompressor().compress(
        msgpack.packb(payload, use_bin_type=True))
    (legacy / "step000000001.ckpt").write_bytes(blob)
    (legacy / "step000000001.json").write_text('{"step": 1, "plan": null}')
    (legacy / "MANIFEST").write_text('{"latest_step": 1}')
    assert_restores(legacy)


# ------------------------------------------------- corrupt/truncated blobs

def test_decode_blob_rejects_garbage_with_clear_error():
    """Anything that is neither GVCK nor a legacy zstd frame is corrupt —
    NOT a cue to demand optional legacy dependencies (the old misleading
    'install zstandard/msgpack' failure mode)."""
    import pytest

    for junk in (b"", b"G", b"GVC", b"JUNKJUNKJUNK", b"\x00" * 64):
        with pytest.raises(ckpt.CorruptCheckpointError, match="corrupt or truncated"):
            ckpt.decode_blob(junk)
        try:
            ckpt.decode_blob(junk)
        except ckpt.CorruptCheckpointError as e:
            assert "msgpack" not in str(e) and "zstandard" not in str(e)


def test_decode_blob_legacy_routing_is_zstd_magic_only():
    """Only a real zstd frame prefix reaches the legacy decoder (whose error
    may legitimately mention the optional deps)."""
    import pytest

    from repro.runtime.compression import LEGACY_ZSTD_MAGIC, _zstd_available

    blob = LEGACY_ZSTD_MAGIC + b"\x00" * 16
    if _zstd_available() and ckpt._have_msgpack():
        with pytest.raises(Exception):      # real decompressor rejects junk
            ckpt.decode_blob(blob)
    else:
        with pytest.raises(RuntimeError, match="legacy checkpoint"):
            ckpt.decode_blob(blob)


def test_header_fuzz_truncated_at_every_boundary():
    """A v1 blob truncated at EVERY byte boundary fails with a clear
    corruption/format error — never the legacy missing-dep error, never an
    uncontrolled struct/json crash, and never silent success."""
    import pytest

    payload = _tiny_payload()
    for codec in ("raw", "zlib"):
        blob = ckpt.encode_blob(payload, codec=codec)
        assert ckpt.decode_blob(blob)["params/w"]["shape"] == [2, 2]
        for i in range(len(blob)):
            with pytest.raises((ckpt.CorruptCheckpointError, ValueError)) as ei:
                ckpt.decode_blob(blob[:i])
            assert "legacy checkpoint" not in str(ei.value)


def test_v2_corrupt_shard_detected(tmp_path, rng):
    cfg, model, plan, hp = _setup(rng)
    params = hp.ungroup(hp.init_params(rng))
    ckpt.save(tmp_path, 1, params, None, plan)
    victim = max((tmp_path / "blobs").glob("*.gvck"),
                 key=lambda p: p.stat().st_size)
    data = bytearray(victim.read_bytes())
    victim.write_bytes(bytes(data[: len(data) // 2]))    # truncate mid-body
    import pytest
    with pytest.raises((ckpt.CorruptCheckpointError, ValueError)):
        ckpt.restore(tmp_path, params_like=params)


def test_v2_shard_hash_mismatch_detected(tmp_path, rng):
    """A shard whose bytes decompress fine but don't match the content hash
    in the index (bit rot, wrong blob store) is refused."""
    cfg, model, plan, hp = _setup(rng)
    params = hp.ungroup(hp.init_params(rng))
    ckpt.save(tmp_path, 1, params, None, plan, codec="raw")
    victim = max((tmp_path / "blobs").glob("*.gvck"),
                 key=lambda p: p.stat().st_size)
    data = bytearray(victim.read_bytes())
    data[-1] ^= 0xFF                                     # flip one payload bit
    victim.write_bytes(bytes(data))
    import pytest
    with pytest.raises(ckpt.CorruptCheckpointError, match="content\\s?hash"):
        ckpt.restore(tmp_path, params_like=params)


# ------------------------------------------------------ path-key escaping

def test_flatten_escapes_separator_no_collision(tmp_path):
    """A literal '/' inside a leaf key must not collide with nesting."""
    tree = {"a/b": np.float32(1.0), "a": {"b": np.float32(2.0)},
            "back\\slash": np.float32(3.0)}
    flat = ckpt._flatten(tree)
    assert len(flat) == 3                     # no silent collision
    assert flat["a/b"] if "a/b" in flat else True
    assert "a\\/b" in flat and "a/b" in flat and "back\\\\slash" in flat
    ckpt.save(tmp_path, 1, tree)
    out = ckpt.restore(tmp_path, params_like=tree)["params"]
    assert float(out["a/b"]) == 1.0
    assert float(out["a"]["b"]) == 2.0
    assert float(out["back\\slash"]) == 3.0


# ------------------------------------------------------------ async writer

def _params_tree(rng):
    k = jax.random.split(rng, 3)
    return {"w": jax.random.normal(k[0], (64, 64)),
            "b": jax.random.normal(k[1], (64,)),
            "emb": jax.random.normal(k[2], (128, 32))}


def test_async_save_bitwise_identical_to_sync(tmp_path, rng):
    import hashlib

    tree = _params_tree(rng)
    sync_dir, async_dir = tmp_path / "sync", tmp_path / "async"
    for step in (1, 2):
        ckpt.save(sync_dir, step, tree, keep=10)
    with ckpt.CheckpointWriter() as w:
        for step in (1, 2):
            w.save_async(async_dir, step, tree, keep=10)

    def digest(root):
        return {str(f.relative_to(root)): hashlib.sha256(f.read_bytes()).hexdigest()
                for f in sorted(root.rglob("*")) if f.is_file()}

    assert digest(sync_dir) == digest(async_dir)


def test_async_writer_drains_on_close_and_wait_returns_path(tmp_path, rng):
    tree = _params_tree(rng)
    w = ckpt.CheckpointWriter()
    for step in range(1, 5):
        w.save_async(tmp_path, step, tree, keep=10)
    path = w.wait()
    assert path == tmp_path / "step000000004.json"
    assert w.saves_started == w.saves_completed == 4
    assert ckpt.latest_step(tmp_path) == 4
    assert w.close() == path                  # idempotent drain
    # writer is reusable after close
    w.save_async(tmp_path, 5, tree, keep=10)
    assert w.close() == tmp_path / "step000000005.json"


def test_async_writer_snapshot_isolates_later_mutation(tmp_path):
    """The snapshot captures values at save_async time: mutating a numpy
    source in place while the save is STILL IN FLIGHT must not leak into
    the written checkpoint (host-backed leaves are value-copied at enqueue;
    device arrays are immutable and pass by reference)."""
    src = np.arange(8, dtype=np.float32)
    tree = {"w": src}
    with ckpt.CheckpointWriter() as w:
        w.save_async(tmp_path, 1, tree, keep=10)
        src += 100.0                      # no wait(): save 1 may be in flight
        w.save_async(tmp_path, 2, tree, keep=10)
        src += 100.0
    out1 = ckpt.restore(tmp_path, 1, params_like=tree)["params"]["w"]
    out2 = ckpt.restore(tmp_path, 2, params_like=tree)["params"]["w"]
    np.testing.assert_array_equal(out1, np.arange(8, dtype=np.float32))
    np.testing.assert_array_equal(out2, np.arange(8, dtype=np.float32) + 100.0)


def test_async_writer_error_surfaces_and_recovers(tmp_path, rng):
    import pytest

    tree = _params_tree(rng)
    w = ckpt.CheckpointWriter()
    blocked = tmp_path / "not-a-dir"
    blocked.write_text("a file where a directory must go")
    w.save_async(blocked, 1, tree)
    with pytest.raises(RuntimeError, match="async checkpoint writer failed"):
        w.wait()
    # the error is raised once, then the writer keeps working
    w.save_async(tmp_path, 2, tree)
    assert w.wait() == tmp_path / "step000000002.json"
    w.close()


def test_async_writer_bounded_queue_double_buffers(tmp_path, rng):
    """With max_pending=1 the caller can always have one save in flight and
    one queued; the third call blocks until the first drains — i.e. the
    step loop only ever waits on the *previous* save."""
    tree = _params_tree(rng)
    w = ckpt.CheckpointWriter(max_pending=1)
    for step in range(1, 8):
        w.save_async(tmp_path, step, tree, keep=10)
    assert w.close() == tmp_path / "step000000007.json"
    assert w.saves_completed == 7


def test_migrate_via_checkpoint_async_matches_sync(rng):
    """The elastic fallback path writes through the async writer by default;
    the escape hatch must be bitwise identical."""
    cfg, model, plan, hp = _setup(rng)
    from repro.runtime import resize
    params = hp.init_params(rng)
    opt = hp.init_opt_state(params)
    p_a, o_a, _, rep_a = resize.migrate_via_checkpoint(
        hp, hp, params, opt, async_write=True)
    p_s, o_s, _, rep_s = resize.migrate_via_checkpoint(
        hp, hp, params, opt, async_write=False)
    assert rep_a.path == rep_s.path == "checkpoint"
    for a, b in zip(jax.tree.leaves((p_a, o_a.m, o_a.v)),
                    jax.tree.leaves((p_s, o_s.m, o_s.v))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
