"""Quickstart: the paper's 4-step workflow in ~30 lines (Fig. 2).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core import get_hybrid_parallel_configs                 # step 1-3
from repro.core.strategy import ExecutionPlan, LayerStrategy
from repro.models import build_model
from repro.runtime.data import SyntheticDataset
from repro.runtime.train import construct_hybrid_parallel_model    # step 4

# 1-3: profile the hardware+model and search the hybrid-parallel plan for a
#      256-chip TPU v5e pod (pure algorithm — runs anywhere)
full_cfg = get_config("qwen3-14b")
plan = get_hybrid_parallel_configs(full_cfg, seq_len=4096, global_batch=256,
                                   mesh_shape=(16, 16), mesh_axes=("data", "model"),
                                   pp_options=[1])
print("searched plan for qwen3-14b @ 256 chips:")
print(f"  strategy mix: {[ (s.short()) for s in set(plan.layer_strategies)]}")
print(f"  grad_accum={plan.grad_accum}  predicted step "
      f"{plan.predicted_step_time:.2f}s  memory {plan.predicted_memory/1e9:.1f} GB/chip")

# 4: run the same runtime at laptop scale on a reduced config
cfg = full_cfg.reduced()
model = build_model(cfg)
strat = LayerStrategy(remat="selective")
local_plan = ExecutionPlan(arch=cfg.name, shape="quickstart", mesh_axes=("data",),
                           mesh_shape=(1,), grad_accum=2,
                           layer_strategies=[strat] * cfg.num_layers,
                           default_strategy=strat)
hp = construct_hybrid_parallel_model(model, local_plan)
params = hp.init_params(jax.random.PRNGKey(0))
opt = hp.init_opt_state(params)
ds = SyntheticDataset(cfg, seq_len=64, global_batch=4)
step = hp.jit_train_step(donate=False)
for i in range(5):
    batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
    params, opt, m = step(params, opt, batch)
    print(f"step {i}: loss {float(m['loss']):.4f}")
print("quickstart OK")
