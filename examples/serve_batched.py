"""Batched serving example (prefill + decode with a sharded-KV-capable
engine) — CPU-scale; the decode_32k/long_500k dry-run cells prove the same
code path at pod scale.

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch import serve as serve_driver

if __name__ == "__main__":
    serve_driver.main(["--arch", "qwen2.5-3b", "--batch", "4",
                       "--prompt-len", "32", "--max-new", "16"])
