"""End-to-end driver example (deliverable b): train a ~100M-param llama-style
model for a few hundred steps with periodic checkpointing and a simulated
elastic event — everything through the public launcher.

NOTE: the synthetic pipeline emits uniform random tokens, so the achievable
loss floor is ln(vocab)=10.37 — the trajectory descends from ~10.92 toward
it (there are no learnable correlations beyond the unigram distribution).

    PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse

from repro.launch import train as train_driver

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt_100m")
    args = ap.parse_args()
    train_driver.main([
        "--preset", "100m",
        "--steps", str(args.steps),
        "--seq", "256",
        "--batch", "16",
        "--remat", "selective",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
        "--log-every", "10",
        "--simulate-failure-at", str(max(args.steps // 2, 1)),
    ])
