"""Explore how the search engine adapts plans to hardware (the paper's core
mechanism): same model, four clusters, four different strategies.

    PYTHONPATH=src python examples/search_strategies.py
"""
from repro.configs.registry import get_config
from repro.core.cluster import (A100_NODE8, H100_NODE8, RTX4090_NODE8,
                                TPU_V5E_POD)
from repro.core.search import SearchEngine

cfg = get_config("qwen3-14b")
print(f"model: {cfg.name}  ({cfg.num_layers} layers)")
print(f"{'cluster':12s} {'step(s)':>8s} {'mem/GB':>7s} {'ga':>3s}  strategies")
for cluster in (A100_NODE8, H100_NODE8, RTX4090_NODE8, TPU_V5E_POD):
    res = SearchEngine(cfg, cluster).search(
        4096, 64 if cluster.chips == 16 else 256,
        total_devices=cluster.chips, mesh_constrained=False,
        mesh_shape=(cluster.chips,), mesh_axes=("data",))
    p = res.plan
    mix = {}
    for s in p.layer_strategies:
        mix[s.short()] = mix.get(s.short(), 0) + 1
    print(f"{cluster.name:12s} {p.predicted_step_time:8.2f} "
          f"{p.predicted_memory/1e9:7.1f} {p.grad_accum:3d}  {mix}")
print("\nEach cluster gets a different plan — that's Galvatron's whole point.")
